"""Elastic controller: Snow membership drives the mesh plan."""
import math

from repro.runtime.elastic import ElasticController, carve


def test_carve_power_of_two():
    assert carve(8).data_parallel == 8
    assert carve(11).data_parallel == 8 and carve(11).spares == 3
    assert carve(16).data_parallel == 16


def test_join_grows_active_set():
    ec = ElasticController(8, seed=1)
    ec.advance(1.0)
    assert len(ec.active_hosts()) == 8
    ec.join_host()
    ec.advance(5.0)
    assert len(ec.active_hosts()) == 9
    assert ec.plan().data_parallel == 8 and ec.plan().spares == 1


def test_graceful_leave_shrinks():
    ec = ElasticController(9, seed=2)
    ec.advance(1.0)
    ec.leave_host(5, graceful=True)
    ec.advance(8.0)
    assert len(ec.active_hosts()) == 8
    assert 5 not in ec.active_hosts()


def test_crash_is_evicted_by_swim():
    ec = ElasticController(8, seed=3)
    ec.advance(1.0)
    ec.leave_host(3, graceful=False)
    ec.advance(10.0)     # SWIM probe + indirect + evict broadcast
    assert 3 not in ec.active_hosts()
    assert ec.plan().data_parallel == 4  # 7 hosts -> dp 4 + 3 spares


def test_meshplan_changed_tracks_previous_carve():
    """Regression: ``changed`` used to be unconditionally True.  Churn
    absorbed by the spare pool (11 -> 10 hosts over a dp=8 axis) must
    NOT report a mesh change; an axis change must."""
    p1 = carve(11)
    assert p1.changed                       # first carve of a fleet
    p2 = carve(10, prev=p1)
    assert p2.data_parallel == 8 and not p2.changed
    p3 = carve(7, prev=p2)
    assert p3.data_parallel == 4 and p3.changed
    p4 = carve(14, prev=p3)
    assert p4.data_parallel == 8 and p4.changed


def test_controller_plan_threads_previous_carve():
    ec = ElasticController(11, seed=6)
    ec.advance(1.0)
    assert ec.plan().changed                # first plan
    assert not ec.plan().changed            # no transition since
    ec.leave_host(9, graceful=True)
    ec.advance(8.0)
    assert not ec.plan().changed            # 10 hosts, dp still 8
    for h in (10, 8, 7):
        ec.leave_host(h, graceful=True)
    ec.advance(8.0)
    assert ec.plan().changed                # 7 hosts -> dp 4


def test_disseminate_reaches_all_live_hosts():
    ec = ElasticController(9, seed=7)
    ec.advance(1.0)
    out = ec.disseminate(1024, settle_s=30.0)
    assert out["delivered"] == 9 and out["reach"] == 1.0
    assert out["converged_s"] > 0 and not math.isnan(out["converged_s"])


def test_recarve_announces_only_on_axis_change():
    ec = ElasticController(9, seed=8)
    ec.advance(1.0)
    first = ec.recarve(settle_s=30.0)
    assert first["changed"] and first["reach"] == 1.0
    ec.leave_host(8, graceful=True)         # 9 -> 8 hosts, dp stays 8
    ec.advance(8.0)
    noop = ec.recarve(settle_s=30.0)
    assert not noop["changed"] and "reach" not in noop
    ec.leave_host(7, graceful=True)         # 8 -> 7 hosts, dp 8 -> 4
    ec.advance(8.0)
    shrink = ec.recarve(settle_s=30.0)
    assert shrink["changed"] and shrink["data_parallel"] == 4
    assert shrink["reach"] == 1.0


def test_straggler_flips_collective_policy():
    ec = ElasticController(4, seed=4)
    for h in range(4):
        ec.report_step(h, 0.1)
    assert ec.collective_policy() == "ring"
    ec.report_step(2, 1.0)
    assert ec.collective_policy() == "two_tree"
    assert 2 in ec.stragglers()
