"""Elastic controller: Snow membership drives the mesh plan."""
from repro.runtime.elastic import ElasticController, carve


def test_carve_power_of_two():
    assert carve(8).data_parallel == 8
    assert carve(11).data_parallel == 8 and carve(11).spares == 3
    assert carve(16).data_parallel == 16


def test_join_grows_active_set():
    ec = ElasticController(8, seed=1)
    ec.advance(1.0)
    assert len(ec.active_hosts()) == 8
    ec.join_host()
    ec.advance(5.0)
    assert len(ec.active_hosts()) == 9
    assert ec.plan().data_parallel == 8 and ec.plan().spares == 1


def test_graceful_leave_shrinks():
    ec = ElasticController(9, seed=2)
    ec.advance(1.0)
    ec.leave_host(5, graceful=True)
    ec.advance(8.0)
    assert len(ec.active_hosts()) == 8
    assert 5 not in ec.active_hosts()


def test_crash_is_evicted_by_swim():
    ec = ElasticController(8, seed=3)
    ec.advance(1.0)
    ec.leave_host(3, graceful=False)
    ec.advance(10.0)     # SWIM probe + indirect + evict broadcast
    assert 3 not in ec.active_hosts()
    assert ec.plan().data_parallel == 4  # 7 hosts -> dp 4 + 3 spares


def test_straggler_flips_collective_policy():
    ec = ElasticController(4, seed=4)
    for h in range(4):
        ec.report_step(h, 0.1)
    assert ec.collective_policy() == "ring"
    ec.report_step(2, 1.0)
    assert ec.collective_policy() == "two_tree"
    assert 2 in ec.stragglers()
