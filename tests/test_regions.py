"""Algorithm 1 (FindNode) properties: exact coverage, no duplicates,
termination, height bound (Eq. 8)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.membership import MembershipView
from repro.core.regions import find_children, partition_balanced
from repro.core.tree import expected_height, trace_broadcast


@given(st.integers(1, 500), st.integers(1, 16))
def test_partition_balanced_covers(count, parts):
    ranges = partition_balanced(count, parts)
    covered = []
    for lo, hi in ranges:
        assert lo <= hi
        covered.extend(range(lo, hi + 1))
    assert covered == list(range(count))
    sizes = [hi - lo + 1 for lo, hi in ranges]
    assert max(sizes) - min(sizes) <= 1


@given(st.integers(2, 400), st.sampled_from([2, 4, 6, 8]),
       st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_broadcast_reaches_everyone_once(n, k, root_seed):
    view = MembershipView(range(n))
    root = root_seed % n
    t = trace_broadcast(root, view, k)
    assert t.delivered == frozenset(range(n))
    assert t.duplicates == 0
    assert t.sends == n - 1          # each node receives exactly once


@given(st.integers(2, 1500), st.sampled_from([2, 4, 8]))
@settings(max_examples=40, deadline=None)
def test_height_within_eq8(n, k):
    view = MembershipView(range(n))
    t = trace_broadcast(0, view, k)
    assert t.height <= expected_height(n, k)


def test_fanout_bounded():
    n, k = 300, 4
    view = MembershipView(range(n))
    t = trace_broadcast(7, view, k)
    for node, kids in t.children.items():
        assert len(kids) <= k, (node, kids)


def test_k_must_be_even():
    view = MembershipView(range(10))
    with pytest.raises(ValueError):
        find_children(view, 0, None, None, 3)
