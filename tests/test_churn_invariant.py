"""The paper's churn-resilience model (Appendix A/B), as properties.

Theorem (App. A): if every node's membership view S satisfies S ⊇ S_p
(the stable set), then every node of S_p receives every broadcast —
regardless of how the views otherwise differ.
"""
import random

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.membership import MembershipView
from repro.core.tree import trace_broadcast, trace_two_trees


def _divergent_views(rng, stable, transients):
    """Each node sees all of `stable` plus an arbitrary transient subset."""
    views = {}
    for node in stable + transients:
        extra = [t for t in transients if t == node or rng.random() < 0.5]
        views[node] = MembershipView(sorted(set(stable + extra)))
    return views


@given(st.integers(4, 120), st.integers(0, 30), st.sampled_from([2, 4, 8]),
       st.integers(0, 2**31))
@settings(max_examples=80, deadline=None)
def test_appendix_a_stable_nodes_always_delivered(n_stable, n_trans, k, seed):
    rng = random.Random(seed)
    stable = list(range(n_stable))
    transients = list(range(1000, 1000 + n_trans))
    views = _divergent_views(rng, stable, transients)
    root = rng.choice(stable)
    t = trace_broadcast(root, views, k)
    missing = set(stable) - set(t.delivered)
    assert not missing, f"stable nodes missed: {sorted(missing)}"


@given(st.integers(4, 80), st.integers(0, 16), st.integers(0, 2**31))
@settings(max_examples=40, deadline=None)
def test_appendix_a_holds_for_coloring(n_stable, n_trans, seed):
    """§4.6: 'The Coloring messages still preserve the churn-tolerant
    property as proven in Appendix A.'"""
    rng = random.Random(seed)
    stable = list(range(n_stable))
    transients = list(range(1000, 1000 + n_trans))
    views = _divergent_views(rng, stable, transients)
    root = rng.choice(stable)
    p, s = trace_two_trees(root, views, 4)
    delivered = set(p.delivered) | set(s.delivered)
    missing = set(stable) - delivered
    assert not missing, f"stable nodes missed: {sorted(missing)}"


def test_appendix_b_partial_nodes_may_or_may_not_receive():
    """Nodes known only to part of the cluster may miss messages — but
    never disturb the fully-known ones (the paper's Fig. 9 scenario)."""
    rng = random.Random(0)
    stable = list(range(8))
    transients = [100, 101, 102]
    misses = 0
    for seed in range(50):
        rng = random.Random(seed)
        views = _divergent_views(rng, stable, transients)
        t = trace_broadcast(0, views, 4)
        assert set(stable) <= set(t.delivered)
        misses += len(set(transients) - set(t.delivered))
    # partially-known nodes DO miss messages sometimes (the trade-off the
    # paper accepts for join/leave)
    assert misses > 0
