"""Node Coloring proofs as properties: Appendix C (off-color nodes are
always leaves) and Appendix D (two disjoint delivery paths)."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.coloring import color_of, tree_color
from repro.core.membership import MembershipView
from repro.core.tree import trace_two_trees


@given(st.integers(3, 300), st.sampled_from([4, 8]), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_both_trees_deliver(n, k, rootseed):
    view = MembershipView(range(n))
    root = rootseed % n
    p, s = trace_two_trees(root, view, k)
    assert p.delivered == frozenset(range(n))
    # the secondary tree covers everyone except (possibly) the initiator
    assert s.delivered >= frozenset(x for x in range(n) if x != root)


@given(st.integers(4, 300), st.sampled_from([4, 8]), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_appendix_c_off_color_nodes_are_leaves(n, k, rootseed):
    n = n - (n % 2)          # even ring: clean parity alternation (paper)
    view = MembershipView(range(n))
    root = rootseed % n
    p, s = trace_two_trees(root, view, k)
    for node in p.children:          # internal nodes of the primary tree
        if node != root:
            assert color_of(view, root, node) == tree_color(0)
    for node in s.children:          # internal nodes of the secondary
        if node != root:             # (initiator only hands off the root)
            assert color_of(view, root, node) == tree_color(1)


@given(st.integers(4, 200), st.sampled_from([4, 8]), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_appendix_d_disjoint_paths(n, k, rootseed):
    n = n - (n % 2)
    view = MembershipView(range(n))
    root = rootseed % n
    p, s = trace_two_trees(root, view, k)
    for x in range(n):
        if x == root:
            continue
        interior_p = set(p.path(x)[1:-1])
        interior_s = set(s.path(x)[1:-1]) - {root}
        overlap = interior_p & interior_s
        assert not overlap, (x, overlap)


def test_double_delivery_count():
    """§4.6: every node receives the message twice (once per tree),
    giving 2× the standard RMR — Table 2's 244 vs 122 bytes."""
    n, k = 100, 4
    view = MembershipView(range(n))
    p, s = trace_two_trees(0, view, k)
    assert p.sends == n - 1
    assert s.sends >= n - 1          # secondary also reaches everyone
