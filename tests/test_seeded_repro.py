"""Seeded reproducibility: identical (seed, scenario) runs must produce
identical Metrics rows for every protocol.

Pins the pre-sampled-delay refactor (DelayBank, block-sampled link
latencies) against accidental RNG-order drift: any change that makes a
draw depend on event interleaving or wall-clock state breaks these.
Message ids come from a process-global counter, so rows are compared
with mids normalized to broadcast order.
"""
import math

import pytest

from repro.core.scenarios import (PROTOCOLS, run_breakdown, run_churn,
                                  run_stable)


def _rows(cluster):
    out = []
    for i, row in enumerate(cluster.metrics.per_message()):
        r = dict(row)
        r["mid"] = i
        out.append(r)
    return out


def _assert_same(rows_a, rows_b, ctx):
    assert len(rows_a) == len(rows_b), ctx
    for a, b in zip(rows_a, rows_b):
        for key in ("mid", "ldt", "reliability", "rmr"):
            va, vb = a[key], b[key]
            if isinstance(va, float) and math.isnan(va):
                assert math.isnan(vb), (ctx, key)
            else:
                assert va == vb, (ctx, key, va, vb)


@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_stable_rows_reproducible(protocol):
    kw = dict(n=80, k=4, n_messages=6, seed=13)
    _assert_same(_rows(run_stable(protocol, **kw)),
                 _rows(run_stable(protocol, **kw)), ("stable", protocol))


@pytest.mark.parametrize("engine", ["events", "vectorized"])
def test_stable_engines_reproducible(engine):
    """Both engine paths individually, not just the auto route."""
    kw = dict(n=80, k=4, n_messages=6, seed=13, engine=engine)
    _assert_same(_rows(run_stable("coloring", **kw)),
                 _rows(run_stable("coloring", **kw)), ("stable", engine))


@pytest.mark.parametrize("protocol", ["snow", "coloring", "gossip", "plumtree"])
def test_churn_rows_reproducible(protocol):
    kw = dict(n=60, k=4, n_messages=15, seed=21, churn_every=5)
    if protocol in ("snow", "coloring"):
        kw["engine"] = "events"     # pin the full-protocol path explicitly
    _assert_same(_rows(run_churn(protocol, **kw)),
                 _rows(run_churn(protocol, **kw)), ("churn", protocol))


@pytest.mark.parametrize("protocol", ["snow", "coloring"])
def test_breakdown_rows_reproducible(protocol):
    kw = dict(n=60, k=4, n_messages=15, seed=8, crash_every=5,
              engine="events")
    _assert_same(_rows(run_breakdown(protocol, **kw)),
                 _rows(run_breakdown(protocol, **kw)), ("breakdown", protocol))


@pytest.mark.parametrize("engine", ["events", "vectorized"])
def test_churn_engines_reproducible(engine):
    """Both churn engine paths individually, not just the auto route."""
    kw = dict(n=60, k=4, n_messages=15, seed=21, churn_every=5,
              engine=engine)
    _assert_same(_rows(run_churn("coloring", **kw)),
                 _rows(run_churn("coloring", **kw)), ("churn", engine))


@pytest.mark.parametrize("engine", ["events", "vectorized"])
def test_breakdown_engines_reproducible(engine):
    kw = dict(n=60, k=4, n_messages=15, seed=8, crash_every=5,
              engine=engine)
    _assert_same(_rows(run_breakdown("snow", **kw)),
                 _rows(run_breakdown("snow", **kw)), ("breakdown", engine))
