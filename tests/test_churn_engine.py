"""Epoch-segmented vectorized engine vs the event loop under churn.

Differential contract (mirrors ``test_engine.py`` for the stable case):

* on **boundary-aligned** traces — no broadcast in flight at any
  membership event — the oracle-membership event loop
  (``run_trace_aligned``) and the closed-form replay
  (``run_trace_vectorized``) agree on every first-delivery time
  exactly, per node, including which nodes a crash blackholes;
* on the **paper cadences** (§5.4/§5.5, events mid-flight) the engines
  are statistically pinned: reliabilities agree to a band, seeded LDT
  and RMR drift stays small.
"""
import math

import numpy as np
import pytest

from repro.core.churn import (ChurnEvent, ChurnTrace, aligned_breakdown_trace,
                              aligned_churn_trace, burst_churn_trace,
                              correlated_failure_trace, flash_crowd_trace,
                              paper_breakdown_trace, paper_churn_trace,
                              rolling_restart_trace)
from repro.core.engine import (run_breakdown_vectorized, run_churn_vectorized,
                               run_trace_vectorized, trace_sweep)
from repro.core.scenarios import (run_breakdown, run_churn,
                                  run_trace_aligned, summarize)


def _paired_mids(ev, vec):
    return list(zip(sorted(ev.metrics.start), sorted(vec.metrics.start)))


def _assert_bit_exact(ev, vec, ctx):
    """Every event-loop first delivery equals the sweep's time exactly,
    and the sweep delivers nowhere the event loop did not."""
    for mid_e, mid_v in _paired_mids(ev, vec):
        fd = ev.metrics.first_delivery[mid_e]
        tv = vec.metrics.times_for(mid_v)
        mem = vec.metrics.members_for(mid_v)
        idx = {int(m): i for i, m in enumerate(mem)}
        for node, t in fd.items():
            assert t == tv[idx[node]], (*ctx, mid_e, node)
        src = int(mem[vec.metrics.src_index[mid_v]])
        delivered_vec = {int(mem[i]) for i in np.nonzero(~np.isnan(tv))[0]
                         if int(mem[i]) != src}
        assert delivered_vec == set(fd), (*ctx, mid_e)
    fixed = set(vec.fixed)
    for a, b in zip(ev.metrics.per_message(fixed),
                    vec.metrics.per_message(fixed)):
        for key in ("ldt", "reliability", "rmr", "rmr_redundant",
                    "payload_bytes", "redundant_bytes", "duplicates"):
            va, vb = a[key], b[key]
            if isinstance(va, float) and math.isnan(va):
                assert math.isnan(vb), (*ctx, key)
            else:
                assert va == vb, (*ctx, key, va, vb)


@pytest.mark.parametrize("protocol", ["snow", "coloring"])
@pytest.mark.parametrize("n", [50, 500, 5000])
def test_churn_engines_bit_exact(protocol, n):
    seed = 3 if n == 5000 else 7
    trace = aligned_churn_trace(n, n_messages=4)
    assert trace.is_boundary_aligned(14.0)
    ev = run_trace_aligned(protocol, trace, k=4, seed=seed)
    vec = run_trace_vectorized(protocol, trace, k=4, seed=seed,
                               backend="numpy")
    _assert_bit_exact(ev, vec, ("churn", protocol, n))


@pytest.mark.parametrize("protocol", ["snow", "coloring"])
@pytest.mark.parametrize("n", [50, 500, 5000])
def test_breakdown_engines_bit_exact(protocol, n):
    seed = 2 if n == 5000 else 9
    trace = aligned_breakdown_trace(n, n_messages=4, seed=seed)
    assert trace.is_boundary_aligned(14.0)
    ev = run_trace_aligned(protocol, trace, k=4, seed=seed)
    vec = run_trace_vectorized(protocol, trace, k=4, seed=seed,
                               backend="numpy")
    _assert_bit_exact(ev, vec, ("breakdown", protocol, n))
    # a crash window must actually depress Reliability below 1
    rel = [r["reliability"] for r in vec.metrics.per_message(set(vec.fixed))]
    assert min(rel) < 1.0, "aligned breakdown trace never blackholed anyone"


def test_crash_blackholes_whole_subtree():
    """A crashed internal node must take its entire region down, not
    just itself — per tree, before the coloring min."""
    from repro.core.engine import stable_plans

    n = 256
    plan = stable_plans("snow", np.arange(n), 0, 4)[0]
    depth, rlen = np.asarray(plan.depth), np.asarray(plan.region_len)
    victim = int(np.argmax(np.where(depth == 1, rlen, 0)))  # fattest subtree
    trace = ChurnTrace(
        n=n, events=(ChurnEvent(5.0, "crash", victim),),
        msg_times=(0.0, 20.0))
    vec = run_trace_vectorized("snow", trace, k=4, seed=0, backend="numpy")
    mids = sorted(vec.metrics.start)
    before = vec.metrics.times_for(mids[0])
    after = vec.metrics.times_for(mids[1])
    assert not np.isnan(before).any()
    lost = int(np.isnan(after).sum())
    assert lost > 1, "internal-node crash must dark a whole subtree"
    rows = vec.metrics.per_message(set(range(n)))
    assert rows[0]["reliability"] == 1.0
    assert rows[1]["reliability"] == (n - 1 - lost) / (n - 1)


@pytest.mark.parametrize("protocol", ["snow", "coloring"])
def test_paper_churn_statistically_pinned(protocol):
    kw = dict(n=200, k=4, n_messages=30, seed=7)
    ev = summarize(run_churn(protocol, engine="events", **kw))
    vc = summarize(run_churn(protocol, engine="vectorized",
                             backend="numpy", **kw))
    assert ev["reliability"] == vc["reliability"] == 1.0
    assert abs(ev["ldt"] - vc["ldt"]) / ev["ldt"] < 0.35
    assert abs(ev["rmr"] - vc["rmr"]) / ev["rmr"] < 0.05


@pytest.mark.parametrize("protocol", ["snow", "coloring"])
def test_paper_breakdown_statistically_pinned(protocol):
    kw = dict(n=200, k=4, n_messages=40, seed=11)
    ev = summarize(run_breakdown(protocol, engine="events", **kw))
    vc = summarize(run_breakdown(protocol, engine="vectorized",
                                 backend="numpy", **kw))
    # crashes must dent Reliability in both engines, by a similar amount
    assert 0.93 < ev["reliability"] < 1.0
    assert 0.93 < vc["reliability"] < 1.0
    assert abs(ev["reliability"] - vc["reliability"]) < 0.02
    assert abs(ev["ldt"] - vc["ldt"]) / ev["ldt"] < 0.35
    assert abs(ev["rmr"] - vc["rmr"]) / ev["rmr"] < 0.05


def test_epoch_segmentation():
    n = 20
    trace = ChurnTrace(
        n=n,
        events=(ChurnEvent(0.5, "join", 20), ChurnEvent(1.5, "crash", 3),
                ChurnEvent(2.5, "evict", 3), ChurnEvent(2.5, "leave", 20),
                ChurnEvent(3.5, "evict", 3)),      # no-op: already evicted
        msg_times=(0.0, 1.0, 2.0, 3.0, 4.0))
    eps = trace.epochs()
    assert [ep.first for ep in eps] == [0, 1, 2, 3]
    assert [ep.count for ep in eps] == [1, 1, 1, 2]   # no-op evict: no split
    assert list(eps[0].members) == list(range(20))
    assert list(eps[1].members) == list(range(20)) + [20]
    assert list(eps[2].crashed) == [3]
    assert list(eps[3].members) == [i for i in range(20) if i != 3]
    assert eps[3].crashed.size == 0


def test_trace_generators_well_formed():
    for trace in (
        paper_churn_trace(50, n_messages=40),
        paper_breakdown_trace(50, n_messages=40, seed=1),
        burst_churn_trace(50, n_messages=40),
        correlated_failure_trace(50, n_messages=30, seed=2),
        flash_crowd_trace(50, n_messages=30),
        rolling_restart_trace(50, n_messages=30, batch=2),
    ):
        ts = [e.t for e in trace.events]
        assert ts == sorted(ts)
        assert all(e.kind in ("join", "leave", "crash", "evict")
                   for e in trace.events)
        # transient ids never collide with the fixed range, never reused
        joins = trace.join_ids()
        assert len(set(joins)) == len(joins)
        assert all(j >= trace.n for j in joins)
        assert trace.epochs(), "every trace must yield at least one epoch"


@pytest.mark.parametrize("mk", [burst_churn_trace, flash_crowd_trace,
                                rolling_restart_trace])
def test_new_families_keep_fixed_nodes_atomic(mk):
    """Join/leave-only churn — however violent — must not cost the fixed
    cohort a single delivery (the paper's §5.4 claim, generalized)."""
    trace = mk(300, n_messages=30)
    c = run_trace_vectorized("snow", trace, k=4, seed=3, backend="numpy")
    assert summarize(c)["reliability"] == 1.0


def test_correlated_failure_dips_then_recovers():
    trace = correlated_failure_trace(300, n_messages=30, group=8,
                                     at_message=10, seed=0)
    c = run_trace_vectorized("snow", trace, k=4, seed=3, backend="numpy")
    rel = [r["reliability"] for r in c.metrics.per_message(set(range(300)))]
    assert min(rel[10:14]) < 1.0, "rack crash must dent the window"
    assert rel[-1] == 1.0, "post-eviction epochs must fully recover"
    assert all(r == 1.0 for r in rel[:10]), "pre-crash epochs unaffected"


def test_wrapper_entry_points_match_scenarios_route():
    """engine.run_churn_vectorized / run_breakdown_vectorized are the
    same computation scenarios.run_churn/run_breakdown dispatch to."""
    kw = dict(n=120, k=4, n_messages=20, seed=5)
    a = summarize(run_churn("snow", engine="vectorized",
                            backend="numpy", **kw))
    b = summarize(run_churn_vectorized("snow", backend="numpy", **kw))
    assert a == b
    a = summarize(run_breakdown("coloring", engine="vectorized",
                                backend="numpy", **kw))
    b = summarize(run_breakdown_vectorized("coloring", backend="numpy", **kw))
    assert a == b


def test_trace_sweep_matches_full_run():
    trace = paper_breakdown_trace(400, n_messages=20, seed=6)
    c = run_trace_vectorized("snow", trace, k=4, seed=6, backend="numpy")
    rows = trace_sweep("snow", trace, 4, seeds=[6], backend="numpy")
    s = c.metrics.summary(set(range(400)))
    assert rows[0]["reliability"] == pytest.approx(s["reliability"], abs=1e-12)
    assert rows[0]["ldt"] == pytest.approx(s["ldt"], rel=1e-12)
    assert rows[0]["rmr"] == pytest.approx(s["rmr"], rel=1e-12)


def test_jax_backend_matches_numpy_under_churn():
    pytest.importorskip("jax")
    trace = paper_churn_trace(400, n_messages=6)
    a = run_trace_vectorized("coloring", trace, k=4, seed=4,
                             backend="numpy")
    b = run_trace_vectorized("coloring", trace, k=4, seed=4, backend="jax")
    for ma, mb in _paired_mids(a, b):
        ta, tb = a.metrics.times_for(ma), b.metrics.times_for(mb)
        assert (np.isnan(ta) == np.isnan(tb)).all()
        np.testing.assert_allclose(ta, tb, rtol=2e-5, atol=2e-5)
