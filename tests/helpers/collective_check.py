"""Subprocess body for test_collectives: equivalence of the Snow
ppermute collectives against psum/broadcast semantics on 8 devices."""
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.collectives.tree_collectives import (snow_allreduce,
                                                snow_broadcast,
                                                snow_reduce,
                                                two_tree_broadcast)
from repro.compat import shard_map

mesh = jax.make_mesh((8,), ("x",))
x = jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)


def run(fn):
    @functools.partial(shard_map, mesh=mesh, in_specs=P("x"),
                       out_specs=P("x"), check_vma=False)
    def body(xx):
        return fn(xx[0])[None]
    return body(x)


for root in (0, 3, 7):
    for k in (2, 4):
        out = run(lambda v: snow_broadcast(v, "x", axis_size=8, root=root, k=k))
        assert jnp.allclose(out, jnp.broadcast_to(x[root], x.shape)), (root, k)

        out = run(lambda v: two_tree_broadcast(v, "x", axis_size=8, root=root, k=k))
        assert jnp.allclose(out, jnp.broadcast_to(x[root], x.shape)), (root, k)

        out = run(lambda v: snow_allreduce(v, "x", axis_size=8, root=root, k=k))
        assert jnp.allclose(out, jnp.broadcast_to(x.sum(0), x.shape)), (root, k)

        out = run(lambda v: snow_reduce(v, "x", axis_size=8, root=root, k=k))
        assert jnp.allclose(out[root], x.sum(0)), (root, k)

# odd payload through the two-tree splitter
out = run(lambda v: two_tree_broadcast(v[:5], "x", axis_size=8, root=1, k=4))
assert jnp.allclose(out, jnp.broadcast_to(x[1, :5], (8, 5)))

# checkpoint distribution fan-out applies the same schedule tree-wide
from repro.checkpoint.distribution import distribute_params, plan_for
params = {"w": x, "b": x[:, 0]}
dist = distribute_params(params, mesh, "x", root=2, k=2)
plan = plan_for(params, 8)
assert plan.payload_bytes == x.size * 4 + 8 * 4
assert plan.est_time_s > 0

print("ALL-OK")
