"""Roofline machinery: HLO parsing, tier attribution, extrapolation."""
import pytest

from repro.roofline.analysis import (RooflineTerms, _shape_bytes,
                                     extrapolate, parse_collectives)
from repro.roofline.tiers import group_stride_max, tier_of


def test_shape_bytes():
    assert _shape_bytes("bf16[16,4096,8192]{2,1,0}") == 16 * 4096 * 8192 * 2
    assert _shape_bytes("f32[80]{0}") == 320
    assert _shape_bytes("(f32[4]{0}, bf16[2,2]{1,0})") == 16 + 8
    assert _shape_bytes("pred[]") == 0 or _shape_bytes("pred[]") == 1


def test_parse_collectives_counts_operands():
    hlo = """
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1},{2,3}}
  %ag = bf16[256,256]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}
  %cp = bf16[128,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
"""
    st = parse_collectives(hlo)
    nb = 128 * 256 * 2
    assert st.bytes_by_op["all-reduce"] == nb
    assert st.bytes_by_op["all-gather"] == nb          # operand (shard) bytes
    assert st.bytes_by_op["collective-permute"] == nb
    assert st.count_by_op == {"all-reduce": 1, "all-gather": 1,
                              "collective-permute": 1}


def test_tier_attribution_strides():
    # consecutive ids (model axis) → ICI
    assert tier_of("all-reduce(...), replica_groups={{0,1,2,3}}", 256) == "ici"
    # stride 256 (pod axis on a 512-device mesh) → DCN
    assert tier_of("all-reduce(...), replica_groups={{0,256}}", 256) == "dcn"
    # iota format, no transpose: consecutive → ICI
    assert tier_of("all-reduce(...), replica_groups=[32,16]<=[512]", 256) == "ici"
    # iota with 2D transpose: column stride = trailing reshape dim
    assert group_stride_max("replica_groups=[16,32]<=[32,16]T(1,0)") == 16
    # pod-axis groups {i, i+256} on the 512-device mesh → DCN
    assert group_stride_max("replica_groups=[256,2]<=[2,256]T(1,0)") == 256
    assert tier_of("ar, replica_groups=[256,2]<=[2,256]T(1,0)", 256) == "dcn"
    # {i, i+2} pairs are intra-pod despite the transpose form
    assert tier_of("ar, replica_groups=[2,256]<=[256,2]T(1,0)", 256) == "ici"


def test_extrapolate_linear():
    p1 = {"flops": 10.0, "bytes": 4.0}
    p2 = {"flops": 16.0, "bytes": 6.0}
    full = extrapolate(p1, p2, 10)
    assert full["flops"] == 10 + 9 * 6
    assert full["bytes"] == 4 + 9 * 2


def test_roofline_terms_and_bottleneck():
    t = RooflineTerms(flops=197e12, hbm_bytes=819e9 * 2, ici_bytes=0,
                      dcn_bytes=0, chips=1, model_flops=98.5e12)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(2.0)
    assert t.bottleneck == "memory"
    assert t.roofline_fraction == pytest.approx(0.25)
    t2 = RooflineTerms(flops=0, hbm_bytes=0, ici_bytes=50e9, dcn_bytes=25e9,
                       chips=1)
    assert t2.t_collective == pytest.approx(2.0)
    assert t2.bottleneck == "collective"
