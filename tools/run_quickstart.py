"""Execute the README quickstart exactly as written.

CI runs this to guarantee the 60-second quickstart works from a fresh
clone: every ``bash`` code fence between the ``<!-- quickstart:begin
-->`` / ``<!-- quickstart:end -->`` markers in ``README.md`` is split
into lines and each non-comment line is run through the shell, from the
repo root, failing fast on the first non-zero exit.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def quickstart_commands(readme: str) -> list:
    m = re.search(r"<!-- quickstart:begin -->(.*?)<!-- quickstart:end -->",
                  readme, re.S)
    if not m:
        raise SystemExit("README.md has no quickstart markers")
    blocks = re.findall(r"```bash\n(.*?)```", m.group(1), re.S)
    cmds = []
    for block in blocks:
        for line in block.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                cmds.append(line)
    if not cmds:
        raise SystemExit("quickstart section contains no bash commands")
    return cmds


def main() -> None:
    cmds = quickstart_commands((ROOT / "README.md").read_text())
    for cmd in cmds:
        print(f"$ {cmd}", flush=True)
        res = subprocess.run(cmd, shell=True, cwd=ROOT)
        if res.returncode != 0:
            raise SystemExit(
                f"quickstart command failed ({res.returncode}): {cmd}")
    print(f"quickstart ok: {len(cmds)} commands ran clean")


if __name__ == "__main__":
    main()
